"""Module scheduling: Algorithm 1 (multi-tuple GenerateConfig) + restricted variants.

Given a module's request rate ``T``, latency budget ``L`` and profile ``P``
(configs ordered by throughput-cost ratio), produce the allocation set.

* ``generate_config``         — paper Algorithm 1 (any number of tuples).
* ``generate_config_ktuple``  — baseline variant limited to K distinct
  configurations (K=1: InferLine/Clipper/Harp-1c, K=2: Nexus/Scrooge/Harp-2c).

Feasibility of a configuration at a point in the greedy walk is checked with
``GetWCL`` under the session's dispatch policy: for TC the batch-collection
rate is the *current unallocated workload* ``rw`` (which, walking in ratio
order, equals Theorem 1's remaining workload ``w_i``).
"""
from __future__ import annotations

import math

import numpy as np

from .dispatch import Alloc, ConfigArrays, Policy, config_arrays, config_wcl, config_wcl_batch
from .profiles import Config, ModuleProfile

_EPS = 1e-9


def get_wcl(
    config: Config, policy: Policy, rw: float, *, full: bool, headroom: float = 0.0,
    burst: float = 0.0,
) -> float:
    """L_wc estimate for a machine at ``config`` when ``rw`` workload remains.

    With ``headroom`` > 0 a full machine is only assigned
    ``(1 - headroom) * throughput`` traffic, so under RR/DT it collects at
    that derated capacity instead of its own throughput (TC collection is the
    remaining *real* workload either way — Theorem 1 is headroom-invariant).

    ``burst`` (seconds) is the burst-aware collection correction downstream
    of batched stages (see `dispatch.config_wcl`).  It applies to every
    machine whose batch actually waits on arrivals: a short-fill machine
    (full or tail) straddles an upstream inter-completion gap just the same.
    """
    if policy is Policy.TC:
        return config_wcl(config, policy, collect_rate=rw, burst=burst)
    if policy in (Policy.RR, Policy.DT):
        # sound model: full machines collect at their own throughput (2d);
        # partial machines cannot collect faster than their assigned rate.
        if headroom > 0.0:
            cap = config.throughput * (1.0 - headroom)
            return config_wcl(
                config, policy, collect_rate=(cap if full else min(rw, cap)),
                full=False, burst=burst,
            )
        rate = config.throughput if full else rw
        if full:
            # 2d short-circuit in config_wcl skips the burst term; a full
            # machine's local collection is still arrival-quantized
            return config_wcl(config, policy, collect_rate=rate, full=True) + burst
        return config_wcl(config, policy, collect_rate=rate, full=False, burst=burst)
    return config_wcl(config, policy, collect_rate=config.throughput)  # DT_OPT


def get_wcl_batch(
    arrs: ConfigArrays, policy: Policy, rw, *, full, headroom: float = 0.0,
    burst: float = 0.0,
) -> np.ndarray:
    """Elementwise `get_wcl` over a whole config table (see `get_wcl`).

    ``rw`` may be a scalar or a per-config array; ``full`` a bool or bool
    array.  Mirrors the scalar branch structure exactly, so the result is
    bit-identical to a per-row `get_wcl` call.
    """
    if policy is Policy.TC:
        return config_wcl_batch(arrs, policy, collect_rate=rw, burst=burst)
    if policy in (Policy.RR, Policy.DT):
        if headroom > 0.0:
            cap = arrs.throughput * (1.0 - headroom)
            if full is True:
                cr = cap
            elif full is False:
                cr = np.minimum(rw, cap)
            else:
                cr = np.where(full, cap, np.minimum(rw, cap))
            return config_wcl_batch(
                arrs, policy, collect_rate=cr, full=False, burst=burst
            )
        if full is True:
            # 2d short-circuit skips the burst term; add it back (see get_wcl)
            return config_wcl_batch(
                arrs, policy, collect_rate=arrs.throughput, full=True
            ) + burst
        part = config_wcl_batch(arrs, policy, collect_rate=rw, full=False, burst=burst)
        if full is False:
            return part
        return np.where(full, 2.0 * arrs.duration + burst, part)
    return config_wcl_batch(arrs, policy, collect_rate=arrs.throughput)  # DT_OPT


def _first_feasible(
    arrs: ConfigArrays, k: int, rw: float, L: float, policy: Policy,
    derate: float, headroom: float, burst: float,
) -> int | None:
    """First config at-or-after ``k`` whose machine holds the budget at
    remaining workload ``rw`` — one batched WCL call over the whole tail
    instead of Algorithm 1's one-at-a-time advance (the remaining workload
    is unchanged while the walk skips infeasible configs, so the batch
    evaluates exactly the feasibility checks the scalar walk would)."""
    if k >= len(arrs):
        return None
    sub = arrs.tail(k)
    full = rw / (sub.throughput * derate) >= 1.0 - 1e-12
    wcl = get_wcl_batch(sub, policy, rw, full=full, headroom=headroom, burst=burst)
    feas = wcl <= L + _EPS
    if not bool(feas.any()):
        return None
    return k + int(np.argmax(feas))


def _merge(allocs: list[Alloc]) -> list[Alloc]:
    """Merge adjacent allocations that share a configuration."""
    out: list[Alloc] = []
    for a in allocs:
        if out and out[-1].config == a.config and out[-1].derate == a.derate:
            prev = out.pop()
            out.append(
                Alloc(
                    a.config,
                    prev.machines + a.machines,
                    prev.rate + a.rate,
                    prev.dummy + a.dummy,
                    derate=a.derate,
                )
            )
        else:
            out.append(a)
    return out


def generate_config(
    T: float,
    L: float,
    profile: ModuleProfile,
    policy: Policy = Policy.TC,
    *,
    headroom: float = 0.0,
    burst: float = 0.0,
    vectorized: bool = True,
) -> tuple[bool, list[Alloc]]:
    """Paper Algorithm 1: greedy multi-tuple configuration generation.

    ``headroom`` provisions machines at ``throughput * (1 - headroom)``: the
    same real workload is spread over proportionally more machines, so each
    machine's batch run period carries slack for timeout-flushed partial
    batches (the paper's zero-slack pacing permanently loses throughput to
    any partial flush).  Feasibility is still checked against the *real*
    collection rates, so the WCL model stays honest.

    ``burst`` (seconds) applies the burst-aware tail correction: a fractional
    tail machine's feasibility is checked at ``d + b/w + burst``, so modules
    fed by upstream batch completions don't get tails whose realized
    collection straddles an upstream inter-batch gap past their budget.

    ``vectorized`` advances past infeasible configurations with one batched
    WCL evaluation over the remaining table (`_first_feasible`) instead of
    the one-config-at-a-time scalar walk; allocations are bit-identical
    either way (the remaining workload does not change while skipping).
    """
    if not 0.0 <= headroom < 1.0:
        raise ValueError(f"headroom must be in [0, 1), got {headroom}")
    if T <= _EPS:
        return True, []
    derate = 1.0 - headroom
    rw = T
    allocs: list[Alloc] = []
    k = 0
    configs = profile.configs  # ratio-descending
    if not configs:
        return False, []
    arrs = config_arrays(configs) if vectorized else None
    c = configs[k]
    while rw > _EPS:
        cap = c.throughput * derate
        n = rw / cap
        full = n >= 1.0 - 1e-12
        if get_wcl(c, policy, rw, full=full, headroom=headroom, burst=burst) <= L + _EPS:
            if full:
                nfull = math.floor(n + 1e-12)
                allocs.append(Alloc(c, float(nfull), nfull * cap, derate=derate))
                rw -= nfull * cap
                if rw < _EPS:
                    rw = 0.0
                # loop re-checks the same c against the smaller rw
            else:
                allocs.append(Alloc(c, n, rw, derate=derate))
                rw = 0.0
        else:
            if vectorized:
                nxt = _first_feasible(
                    arrs, k + 1, rw, L, policy, derate, headroom, burst
                )
                k = len(configs) if nxt is None else nxt
            else:
                k += 1
            if k >= len(configs):
                # No configuration can serve the residual fractionally (a tiny
                # rate cannot even fill a batch of 1 within the budget).  Fall
                # back to DUMMY-FILLING one machine: the frontend pads the
                # residual to a full machine's throughput, so the batch
                # collects at rate t (L_wc = 2d) at the price of one machine.
                fill = _dummy_fill(
                    rw, L, configs, policy, headroom=headroom, burst=burst,
                    vectorized=vectorized,
                )
                if fill is None:
                    return False, []
                allocs.append(fill)
                rw = 0.0
                break
            c = configs[k]
    return True, _merge(allocs)


def _dummy_fill(
    rw: float, L: float, configs, policy: Policy, *, headroom: float = 0.0,
    burst: float = 0.0, vectorized: bool = True,
) -> Alloc | None:
    """Cheapest single machine that can carry ``rw`` when padded with dummies.

    The burst correction applies here too: the padding phantoms are injected
    at the frontend's rate-limited pace, so a bursty upstream still leaves
    the dummy-filled machine's collection quantized by its real arrivals.
    """
    derate = 1.0 - headroom
    best = None
    if vectorized and configs:
        arrs = config_arrays(tuple(configs))
        caps = arrs.throughput * derate
        wcl = get_wcl_batch(arrs, policy, caps, full=True, headroom=headroom)
        ok = ~(caps < rw - _EPS) & ~(wcl + burst > L + _EPS)
        if bool(ok.any()):
            # np.argmin's first-min tie matches the scalar strict-< first-wins
            best = configs[int(np.argmin(np.where(ok, arrs.unit_price, math.inf)))]
    elif not vectorized:
        for c in configs:
            if c.throughput * derate < rw - _EPS:
                continue
            wcl = get_wcl(c, policy, c.throughput * derate, full=True, headroom=headroom)
            if wcl + burst > L + _EPS:
                continue
            if best is None or c.unit_price < best.unit_price:
                best = c
    if best is None:
        return None
    return Alloc(best, 1.0, rw, dummy=best.throughput * derate - rw, derate=derate)


def _cover_with_config(
    c: Config,
    rate: float,
    L: float,
    policy: Policy,
    *,
    collect_rate: float,
    allow_dummy: bool,
) -> list[Alloc] | None:
    """Serve ``rate`` entirely with machines at ``c`` within ``L``, or None.

    With ``allow_dummy`` the fractional tail machine may be dummy-filled when
    its own rate cannot collect a batch in time (prior systems' early-exec /
    over-provisioned residual machine — still one machine's price).
    """
    nfull = math.floor(rate / c.throughput + 1e-12)
    frac_rate = rate - nfull * c.throughput
    if nfull > 0 and get_wcl(c, policy, collect_rate, full=True) > L + _EPS:
        return None
    out = []
    if nfull > 0:
        out.append(Alloc(c, float(nfull), nfull * c.throughput))
    if frac_rate > _EPS:
        if get_wcl(c, policy, frac_rate, full=False) <= L + _EPS:
            out.append(Alloc(c, frac_rate / c.throughput, frac_rate))
        elif allow_dummy and get_wcl(c, policy, c.throughput, full=True) <= L + _EPS:
            out.append(Alloc(c, 1.0, frac_rate, dummy=c.throughput - frac_rate))
        else:
            return None
    return out


def _cover_residual(
    configs, rate: float, L: float, policy: Policy, *, collect_rate: float
) -> list[Alloc] | None:
    """Fractional coverage by the best-ratio config first; dummy-fill last."""
    for allow_dummy in (False, True):
        for c in configs:
            cover = _cover_with_config(
                c, rate, L, policy, collect_rate=collect_rate, allow_dummy=allow_dummy
            )
            if cover is not None:
                return cover
    return None


def _cover_index(
    arrs: ConfigArrays, rate: float, L: float, policy: Policy, *, collect_rate: float
) -> tuple[int, bool] | None:
    """Batched `_cover_residual` screen: the first config (and whether its
    tail needs dummy-filling) that can cover ``rate``, from three WCL
    batches instead of up to ``2 * |configs|`` scalar cover attempts.  The
    winner is then constructed by the scalar `_cover_with_config` (which
    cannot fail for a screened index)."""
    t = arrs.throughput
    nfull = np.floor(rate / t + 1e-12)
    frac = rate - nfull * t
    head_ok = (nfull <= 0) | (
        get_wcl_batch(arrs, policy, collect_rate, full=True) <= L + _EPS
    )
    part_ok = get_wcl_batch(arrs, policy, frac, full=False) <= L + _EPS
    dummy_ok = get_wcl_batch(arrs, policy, t, full=True) <= L + _EPS
    no_frac = frac <= _EPS
    for allow_dummy, tail_ok in (
        (False, no_frac | part_ok),
        (True, no_frac | part_ok | dummy_ok),
    ):
        mask = head_ok & tail_ok
        if bool(mask.any()):
            return int(np.argmax(mask)), allow_dummy
    return None


def generate_config_ktuple(
    T: float,
    L: float,
    profile: ModuleProfile,
    policy: Policy,
    k_tuples: int,
    *,
    vectorized: bool = True,
) -> tuple[bool, list[Alloc]]:
    """K-restricted scheduling used by prior systems.

    K=1: one configuration must carry the whole workload (incl. its fractional
    tail machine).  K=2: best-ratio feasible config for the majority
    (``floor(T/t)`` full machines), then ONE further config for the residual.

    ``vectorized`` screens cover feasibility with batched WCL calls
    (`_cover_index`) and constructs only the winning cover; the scalar
    double loop is the bit-exactness oracle.
    """
    if T <= _EPS:
        return True, []
    configs = profile.configs
    if not configs:
        return False, []
    arrs = config_arrays(configs) if vectorized else None
    if k_tuples <= 1:
        if vectorized:
            hit = _cover_index(arrs, T, L, policy, collect_rate=T)
            if hit is None:
                return False, []
            idx, allow_dummy = hit
            cover = _cover_with_config(
                configs[idx], T, L, policy, collect_rate=T, allow_dummy=allow_dummy
            )
            return True, _merge(cover)
        for allow_dummy in (False, True):
            for c in configs:
                cover = _cover_with_config(
                    c, T, L, policy, collect_rate=T, allow_dummy=allow_dummy
                )
                if cover is not None:
                    return True, _merge(cover)
        return False, []
    # K == 2 (the paper's two-tuple <c_opt, c_res>): greedy two-round heuristic
    # of prior systems — first feasible (max-ratio) majority config, then the
    # first config that can carry the residual including its tail machine.
    if vectorized:
        majorities = np.nonzero(
            get_wcl_batch(arrs, policy, T, full=True) <= L + _EPS
        )[0]
    else:
        majorities = [
            j for j, c in enumerate(configs)
            if get_wcl(c, policy, T, full=True) <= L + _EPS
        ]
    for j in majorities:
        c = configs[int(j)]
        nfull = math.floor(T / c.throughput + 1e-12)
        allocs = []
        res = T
        if nfull >= 1:
            allocs.append(Alloc(c, float(nfull), nfull * c.throughput))
            res = T - nfull * c.throughput
        if res <= _EPS:
            return True, _merge(allocs)
        if vectorized:
            hit = _cover_index(arrs, res, L, policy, collect_rate=res)
            cover = None
            if hit is not None:
                cover = _cover_with_config(
                    configs[hit[0]], res, L, policy, collect_rate=res,
                    allow_dummy=hit[1],
                )
        else:
            cover = _cover_residual(configs, res, L, policy, collect_rate=res)
        if cover is not None:
            return True, _merge(allocs + cover)
        # greedy majority left an infeasible residual: try next majority config
    return False, []
