"""MoE: routing, local ragged path vs explicit per-expert loop, EP shard_map."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# JAX-compile-heavy (jits real kernels/models); deselect with -m "not slow"
pytestmark = pytest.mark.slow

from repro.configs import SMOKE_ARCHS
from repro.models.moe import expert_ffn_local, moe_forward, moe_init, route


CFG = SMOKE_ARCHS["qwen2-moe-a2.7b"]


def test_route_shapes_and_normalization():
    p = moe_init(jax.random.key(0), CFG, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (10, CFG.d_model))
    ids, gates, aux = route(p, CFG, x)
    assert ids.shape == (10, CFG.top_k)
    assert gates.shape == (10, CFG.top_k)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)
    assert bool((ids >= 0).all()) and bool((ids < CFG.n_experts).all())
    assert float(aux) > 0  # switch aux loss is >= 1 for any routing


def test_local_path_matches_explicit_expert_loop():
    """sort+ragged_dot == gather-per-expert dense reference."""
    p = moe_init(jax.random.key(0), CFG, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (16, CFG.d_model)) * 0.5
    out, _ = expert_ffn_local(p, CFG, x)

    ids, gates, _ = route(p, CFG, x)
    expected = np.zeros_like(np.asarray(x))
    for i in range(x.shape[0]):
        for j in range(CFG.top_k):
            e = int(ids[i, j])
            h1 = np.asarray(x[i]) @ np.asarray(p["w1"][e])
            h3 = np.asarray(x[i]) @ np.asarray(p["w3"][e])
            act = h1 / (1 + np.exp(-h1))  # silu
            y = (act * h3) @ np.asarray(p["w2"][e])
            expected[i] += float(gates[i, j]) * y
    np.testing.assert_allclose(np.asarray(out), expected, rtol=2e-4, atol=2e-4)


def test_moe_grads_flow_through_ragged_dot():
    p = moe_init(jax.random.key(0), CFG, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 8, CFG.d_model)) * 0.5

    def loss(p):
        y, aux = moe_forward(p, CFG, x)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    for k in ("w1", "w2", "w3", "router"):
        leaf = g[k]["w"] if isinstance(g[k], dict) else g[k]
        assert float(jnp.abs(leaf).sum()) > 0, k
        assert bool(jnp.all(jnp.isfinite(leaf))), k


_EP_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.configs import SMOKE_ARCHS
    from repro.models.moe import MoEMeshInfo, moe_forward, moe_init

    cfg = SMOKE_ARCHS["qwen2-moe-a2.7b"].replace(moe_capacity_factor=8.0)
    p = moe_init(jax.random.key(0), cfg, jnp.float32, ep=4)
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model)) * 0.5
    y_local, _ = moe_forward(p, cfg, x)

    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    info = MoEMeshInfo(
        ep_axes=("model",), ep_size=4,
        token_axes=("data", "model"), token_size=8,
        mesh=mesh, all_axes=("data", "model"),
    )
    with mesh:
        y_ep, _ = jax.jit(lambda p, x: moe_forward(p, cfg, x, mesh_info=info))(p, x)
    err = float(jnp.max(jnp.abs(y_ep - y_local)) / (jnp.max(jnp.abs(y_local)) + 1e-9))
    assert err < 1e-5, err
    print("EP-OK", err)
    """
)


def test_ep_shard_map_matches_local_8_devices():
    """EP all_to_all path == local path, on 8 fake devices (subprocess)."""
    r = subprocess.run(
        [sys.executable, "-c", _EP_SCRIPT],
        capture_output=True,
        text=True,
        timeout=300,
        cwd=".",
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "EP-OK" in r.stdout


def test_capacity_drop_degrades_gracefully():
    """Tiny capacity drops tokens but output stays finite and bounded."""
    cfg = CFG.replace(moe_capacity_factor=0.25)
    p = moe_init(jax.random.key(0), cfg, jnp.float32, ep=1)
    x = jax.random.normal(jax.random.key(1), (32, cfg.d_model))
    from repro.models.moe import expert_ffn_ep, MoEMeshInfo

    # ep_size=1: all_to_all over a single "axis" degenerates; use local path
    # with an artificially low capacity via the EP body on one device
    out, aux = expert_ffn_local(p, cfg, x)
    assert bool(jnp.all(jnp.isfinite(out)))
