"""The Harpagon planner: dispatch model ∘ latency splitting ∘ module scheduling.

``Planner`` composes the three levels of the paper (Fig. 3):

1. pick the dispatch policy (which fixes every L_wc estimate),
2. split the end-to-end SLO into per-module budgets (Sec. III-D),
3. schedule each module with Algorithm 1 + residual optimizers (Sec. III-C),
4. reassign leftover end-to-end latency to residual workloads (Sec. III-C).

Every baseline system and every Harp-* ablation of the paper is an options
preset over the same composition (see `repro.core.baselines`).
"""
from __future__ import annotations

import math
import time
from contextlib import nullcontext
from dataclasses import dataclass, field, replace
from typing import Mapping

from .dag import Workload
from .dispatch import Policy, collect_capacity, wcl_memo
from .profiles import Config, ModuleProfile
from .residual import ModuleSchedule, apply_reassign, schedule_module
from . import splitter as sp

_EPS = 1e-9


@dataclass(frozen=True)
class PlannerOptions:
    name: str = "harpagon"
    policy: Policy = Policy.TC
    k_tuples: int | None = None          # None = multi-tuple (Algorithm 1)
    split: str = "lc"                    # lc | throughput | even | quantized | dp
    quantize: float = 0.01               # interval for split="quantized"
    node_merge: bool = True
    cost_direct: bool = True
    use_dummy: bool = True
    reassign: int = 10 ** 6              # max reassigner iterations (0 / 1 / many)
    hardware: str | None = None          # None=all, "cheapest", "most_expensive"
    max_batch: int | None = None         # 1 => batching disabled (Harp-nb)
    headroom: float = 0.0                # provision machines at t*(1-headroom):
    #   slack absorbs timeout-flushed partial batches (multi-tuple scheduler
    #   only; 0.0 = paper's zero-slack pacing).  Costs ~1/(1-headroom) more.
    burst_aware: bool = False            # burst-aware tail WCL correction:
    #   downstream of a batched stage, arrivals come quantized in upstream
    #   batch completions, so a fractional tail machine's realized collection
    #   can straddle one upstream batch-arrival quantum b_up / rate_up beyond
    #   its steady-state Theorem-1 fill time (the PR-3 finding).  When on,
    #   tail feasibility is checked at d + b/w + burst, so the scheduler
    #   places tails that hold their budget under batched hand-off.  Off =
    #   paper semantics (golden equivalence).
    vectorized: bool = True              # batched numpy WCL cascade: Algorithm
    #   1's config walk, the dummy generator and the whole splitter evaluate
    #   candidate (config, remaining-workload) tuples as arrays in one
    #   `config_wcl_batch` call instead of memoized scalar `config_wcl`
    #   cascades.  Plans are bit-identical either way; False selects the
    #   scalar reference path (the bit-exactness oracle), which runs under
    #   `dispatch.wcl_memo`.


@dataclass(frozen=True)
class Plan:
    workload: Workload
    options: PlannerOptions
    schedules: Mapping[str, ModuleSchedule]
    feasible: bool
    runtime_s: float
    # -- control-plane identity: plans are live, versioned objects ----------
    version: int = 0                     # bumped by Planner.replan
    provenance: Mapping[str, str] = field(default_factory=dict)
    #   per-module replan action: "reused" | "repaired" | "cached" | "cold"
    #   (empty for a cold plan() — every module was solved from scratch)

    @property
    def cost(self) -> float:
        if not self.feasible:
            return math.inf
        return sum(s.cost for s in self.schedules.values())

    @property
    def e2e_latency(self) -> float:
        if not self.feasible:
            return math.inf
        return self.workload.app.latency({m: s.wcl for m, s in self.schedules.items()})

    def summary(self) -> str:
        hr = f" headroom={self.options.headroom:g}" if self.options.headroom else ""
        lines = [
            f"plan[{self.options.name}] v{self.version} app={self.workload.app.name}"
            f" slo={self.workload.slo}"
            f" feasible={self.feasible} cost={self.cost:.4g} e2e={self.e2e_latency:.4g}"
            f"{hr} runtime={self.runtime_s * 1e3:.2f}ms"
        ]
        for m, s in self.schedules.items():
            prov = self.provenance.get(m)
            tag = f" [{prov}]" if prov else ""
            lines.append(
                f"  {m}:{tag} rate={s.rate:.4g} dummy={s.dummy:.4g} "
                f"budget={s.budget:.4g} wcl={s.wcl:.4g} cost={s.cost:.4g}"
            )
            # epoch-by-epoch plan logs must be auditable: every alloc line
            # carries its dummy rate and headroom derate explicitly, zero or not
            for a in s.allocs:
                lines.append(
                    f"    {a.machines:.4g}x b{a.config.batch}@{a.config.hardware}"
                    f" rate={a.rate:.4g} dummy={a.dummy:.4g} derate={a.derate:.4g}"
                )
        return "\n".join(lines)

    def diff(self, other: "Plan") -> "PlanDelta":
        """Module-by-module delta from ``self`` to ``other`` (see PlanDelta)."""
        return diff_plans(self, other)


def _machines_by_config(s: ModuleSchedule) -> dict[Config, float]:
    out: dict[Config, float] = {}
    for a in s.allocs:
        out[a.config] = out.get(a.config, 0.0) + a.machines
    return out


@dataclass(frozen=True)
class ModuleDelta:
    """One module's change between two plan versions.

    ``added`` / ``drained`` are machine-count changes per configuration
    (fractional tails included), ``dummy_*`` the provisioned phantom rate
    ``sum(a.dummy)`` the frontend streams, and ``action`` how the replan
    resolved the module ("reused" | "repaired" | "cached" | "cold").
    """

    module: str
    rate_before: float
    rate_after: float
    added: tuple[tuple[Config, float], ...]
    drained: tuple[tuple[Config, float], ...]
    dummy_before: float
    dummy_after: float
    action: str = "cold"

    @property
    def changed(self) -> bool:
        return bool(self.added or self.drained) or (
            abs(self.dummy_after - self.dummy_before) > 1e-9
        )

    @property
    def machines_added(self) -> float:
        return sum(n for _, n in self.added)

    @property
    def machines_drained(self) -> float:
        return sum(n for _, n in self.drained)


@dataclass(frozen=True)
class PlanDelta:
    """The diff between two plan versions: what the serving layer must apply.

    A hot-swap is exactly this object realized against live stages: drained
    machines finish their open batch and retire, added machines join the
    dispatch walk, and dummy streamers re-anchor to the new provisioned rate.
    """

    version_from: int
    version_to: int
    cost_before: float
    cost_after: float
    modules: Mapping[str, ModuleDelta]

    @property
    def changed_modules(self) -> tuple[str, ...]:
        return tuple(m for m, d in self.modules.items() if d.changed)

    @property
    def empty(self) -> bool:
        return not self.changed_modules

    def summary(self) -> str:
        head = (
            f"delta v{self.version_from}->v{self.version_to}"
            f" cost {self.cost_before:.4g}->{self.cost_after:.4g}"
        )
        lines = [head]
        for m, d in self.modules.items():
            if not d.changed:
                continue
            add = "+".join(f"{n:.3g}x b{c.batch}@{c.hardware}" for c, n in d.added)
            drain = "+".join(f"{n:.3g}x b{c.batch}@{c.hardware}" for c, n in d.drained)
            lines.append(
                f"  {m}[{d.action}]: rate {d.rate_before:.4g}->{d.rate_after:.4g}"
                f" add[{add}] drain[{drain}]"
                f" dummy {d.dummy_before:.4g}->{d.dummy_after:.4g}"
            )
        return "\n".join(lines)


def diff_plans(prev: Plan, new: Plan) -> PlanDelta:
    """Per-module machine/config/dummy diff between two plans of one app."""
    if prev.workload.app.name != new.workload.app.name:
        raise ValueError("can only diff plans of the same application")
    modules: dict[str, ModuleDelta] = {}
    for m in new.workload.app.modules:
        s0, s1 = prev.schedules.get(m), new.schedules.get(m)
        by0 = _machines_by_config(s0) if s0 else {}
        by1 = _machines_by_config(s1) if s1 else {}
        added, drained = [], []
        for c in {**by0, **by1}:
            d = by1.get(c, 0.0) - by0.get(c, 0.0)
            if d > 1e-9:
                added.append((c, d))
            elif d < -1e-9:
                drained.append((c, -d))
        modules[m] = ModuleDelta(
            module=m,
            rate_before=s0.rate if s0 else 0.0,
            rate_after=s1.rate if s1 else 0.0,
            added=tuple(added),
            drained=tuple(drained),
            dummy_before=sum(a.dummy for a in s0.allocs) if s0 else 0.0,
            dummy_after=sum(a.dummy for a in s1.allocs) if s1 else 0.0,
            action=new.provenance.get(m, "cold"),
        )
    return PlanDelta(
        version_from=prev.version,
        version_to=new.version,
        cost_before=prev.cost,
        cost_after=new.cost,
        modules=modules,
    )


_INFEASIBLE = object()


class Planner:
    def __init__(self, options: PlannerOptions | None = None, *, cache_size: int = 128):
        self.options = options or PlannerOptions()
        # replan memo: quantized-rate-vector -> guard-cleared Plan.  A control
        # loop walking a diurnal cycle revisits rate buckets (the falling
        # phase mirrors the rising one; periods repeat), so hot-swap replans
        # amortize to a dict lookup in steady state.
        self._replan_cache: dict[tuple, Plan] = {}
        self._cache_size = cache_size

    def _cache_key(
        self,
        wl: Workload,
        tolerance: float,
        profiles: "Mapping[str, ModuleProfile] | None" = None,
    ) -> tuple:
        # the tolerance is part of the key: the same bucket integer under a
        # different quantization step maps to a completely different rate
        q = math.log1p(max(tolerance, 1e-6))
        # so is a cheap profile fingerprint: a control loop correcting
        # profiles toward measured durations must not replay plans memoized
        # under the uncorrected (or differently corrected) durations
        fp = ()
        if profiles is not None:
            fp = tuple(
                (m, len(p.configs), round(sum(c.duration for c in p.configs), 12))
                for m, p in sorted(profiles.items())
            )
        return (
            wl.app.name,
            round(wl.slo, 9),
            round(q, 12),
            tuple(
                int(round(math.log(max(float(wl.rates[m]), 1e-12)) / q))
                for m in wl.app.modules
            ),
            fp,
        )

    # -- profile preparation -------------------------------------------------
    def _profiles(
        self, profiles: Mapping[str, ModuleProfile]
    ) -> Mapping[str, ModuleProfile] | None:
        o = self.options
        out = {}
        for m, p in profiles.items():
            hw = None
            if o.hardware == "cheapest":
                hw = [p.cheapest_hardware()]
            elif o.hardware == "most_expensive":
                hw = [p.most_expensive_hardware()]
            p = p.restrict(max_batch=o.max_batch, hardware=hw)
            if not p.configs:
                return None
            out[m] = p
        return out

    # -- splitting ------------------------------------------------------------
    def _split_with(
        self, wl: Workload, profiles: Mapping[str, ModuleProfile], split: str
    ) -> dict[str, float] | None:
        o = self.options
        if split in ("lc", "lc_int"):
            return sp.split_lc(
                wl,
                profiles,
                o.policy,
                node_merge=o.node_merge,
                cost_direct=o.cost_direct,
                integer_tails=split == "lc_int",
                vectorized=o.vectorized,
            )
        if split == "throughput":
            return sp.split_throughput(
                wl, profiles, o.policy, vectorized=o.vectorized
            )
        if split in ("even", "even_int"):
            return sp.split_even(
                wl, profiles, o.policy, integer_tails=split == "even_int",
                vectorized=o.vectorized,
            )
        if split == "quantized":
            return sp.split_quantized(
                wl, profiles, o.policy, q=o.quantize, vectorized=o.vectorized
            )
        if split == "dp":
            return sp.split_dp(
                wl, profiles, o.policy,
                use_dummy=o.use_dummy and o.k_tuples is None,
            )
        raise ValueError(f"unknown splitter {split}")

    # -- full pipeline ---------------------------------------------------------
    def plan(self, wl: Workload, profiles: Mapping[str, ModuleProfile]) -> Plan:
        """Split -> schedule -> residual-optimize.

        Per the paper (Fig. 3) the module scheduler and latency splitter
        iterate: when the LC split's fractionally-tight budgets turn out to
        be integer-unschedulable, Harpagon retries with progressively looser
        splitting strategies and keeps the cheapest feasible plan.  On the
        default ``vectorized`` path every tier evaluates its candidate
        (config, remaining-workload) tuples with the batched WCL kernel
        (`dispatch.config_wcl_batch`); the scalar oracle path
        (``vectorized=False``) instead runs the whole cascade under one
        `dispatch.wcl_memo` scope, which collapses its repeated scalar
        ``(config, rate, burst)`` WCL tuples to dict hits — the
        "millisecond-level planning" claim is tracked (and smoke-gated) by
        the ``planner_speed`` benchmark row.
        """
        t0 = time.perf_counter()
        o = self.options
        best: Plan | None = None
        restricted = self._profiles(profiles)
        if restricted is None:
            return Plan(wl, o, {}, False, time.perf_counter() - t0)
        cascade = [o.split]
        if o.split == "lc":
            # schedule-aware refinement (paper Fig. 3's scheduler<->splitter
            # iteration): looser heuristics + integer-tail-aware budgets
            cascade += ["throughput", "lc_int", "even_int"]
        with nullcontext() if o.vectorized else wcl_memo():
            for split in cascade:
                plan = self._plan_with_split(wl, restricted, split, t0)
                if plan.feasible and (best is None or plan.cost < best.cost - 1e-12):
                    best = plan
        if best is not None:
            return best
        return Plan(wl, o, {}, False, time.perf_counter() - t0)

    def _plan_with_split(
        self,
        wl: Workload,
        restricted: Mapping[str, ModuleProfile],
        split: str,
        t0: float,
    ) -> Plan:
        """One cascade tier over already-restricted profiles (`_profiles`)."""
        o = self.options
        budgets = self._split_with(wl, restricted, split)
        if budgets is None:
            return Plan(wl, o, {}, False, time.perf_counter() - t0)

        # per-module scheduling (Algorithm 1 / k-tuple variants + dummy);
        # wl.app.modules is SP-leaf (topological) order, so a module's burst
        # correction can read its parents' already-fixed schedules
        schedules: dict[str, ModuleSchedule] = {}
        gap = wl.slo - wl.app.latency(budgets)
        for m in wl.app.modules:
            burst = self._burst_of(wl, schedules, m)
            s = schedule_module(
                m,
                wl.rates[m],
                budgets[m],
                restricted[m],
                o.policy,
                use_dummy=o.use_dummy and o.k_tuples is None,
                k_tuples=o.k_tuples,
                headroom=o.headroom,
                burst=burst,
                vectorized=o.vectorized,
            )
            if s is None and gap > _EPS:
                # fallback: spend the global slack on this module's budget
                s = schedule_module(
                    m,
                    wl.rates[m],
                    budgets[m] + gap,
                    restricted[m],
                    o.policy,
                    use_dummy=o.use_dummy and o.k_tuples is None,
                    k_tuples=o.k_tuples,
                    headroom=o.headroom,
                    burst=burst,
                    vectorized=o.vectorized,
                )
                if s is not None:
                    gap = max(0.0, gap - max(0.0, s.wcl - budgets[m]))
            if s is None:
                return Plan(wl, o, {}, False, time.perf_counter() - t0)
            schedules[m] = s

        # latency reassigner: hand the remaining end-to-end gap to residuals
        if o.reassign > 0 and o.k_tuples is None:
            self._reassign(wl, restricted, schedules)

        e2e = wl.app.latency({m: s.wcl for m, s in schedules.items()})
        feasible = e2e <= wl.slo + 1e-6
        return Plan(wl, o, schedules, feasible, time.perf_counter() - t0)

    def _burst_of(
        self, wl: Workload, schedules: Mapping[str, ModuleSchedule], m: str
    ) -> float:
        """Burst-aware tail correction for ``m``: one upstream batch quantum.

        Arrivals at ``m`` come in its parents' batch completions, so a tail
        machine's collection can straddle an inter-completion gap — up to one
        upstream batch's worth of arrival time ``max(b_up) / rate_up`` (the
        realized overshoot observed via the pipeline's overrun attribution).
        Zero for source modules or with ``burst_aware`` off.
        """
        if not self.options.burst_aware:
            return 0.0
        burst = 0.0
        for p in wl.app.parents(m):
            s = schedules.get(p)
            if s is None or not s.allocs:
                continue
            b_up = max(a.config.batch for a in s.allocs)
            burst = max(burst, b_up / max(s.rate, _EPS))
        return burst

    def _reassign(
        self,
        wl: Workload,
        profiles: Mapping[str, ModuleProfile],
        schedules: dict[str, ModuleSchedule],
        max_iters: int | None = None,
    ) -> None:
        o = self.options
        for _ in range(min(o.reassign, max_iters if max_iters is not None else 64)):
            e2e = wl.app.latency({m: s.wcl for m, s in schedules.items()})
            gap = wl.slo - e2e
            if gap <= 1e-9:
                return
            best: tuple[float, str, ModuleSchedule] | None = None
            for m, s in schedules.items():
                new_allocs, _over = apply_reassign(
                    s.rate + s.dummy, s.budget, gap, profiles[m], list(s.allocs),
                    o.policy, headroom=o.headroom,
                    burst=self._burst_of(wl, schedules, m),
                    vectorized=o.vectorized,
                )
                cand = replace(s, allocs=tuple(new_allocs))
                dcost = s.cost - cand.cost
                if dcost <= 1e-12:
                    continue
                # feasibility: the module's wcl may grow, re-check end-to-end
                trial = {
                    k: (cand.wcl if k == m else v.wcl) for k, v in schedules.items()
                }
                if wl.app.latency(trial) <= wl.slo + 1e-9 and (
                    best is None or dcost > best[0]
                ):
                    best = (dcost, m, cand)
            if best is None:
                return
            schedules[best[1]] = best[2]

    # -- incremental repair ----------------------------------------------------
    def replan(
        self,
        prev: Plan,
        new_rates: Mapping[str, float],
        profiles: Mapping[str, ModuleProfile],
        *,
        tolerance: float = 0.02,
        cost_guard: float = 0.01,
        force: "frozenset[str] | set[str]" = frozenset(),
    ) -> Plan:
        """Warm-start incremental repair of ``prev`` for ``new_rates``.

        Reuses the previous per-module budgets (the expensive splitter
        cascade is skipped entirely) and the previous allocation covers:

        * a module whose rate moved at most ``tolerance`` (relative) and
          still fits the provisioned collect capacity is **reused** as-is —
          the provisioned dummy/slack absorbs the drift;
        * a module beyond tolerance is **repaired**: Algorithm 1 re-solves it
          at the new rate under the *previous* budget (the split barely moves
          for moderate rate changes);
        * a cost regression beyond ``cost_guard`` over the rate-scaled
          previous cost — or any repair failure — falls back to a **cold**
          re-split (full cascade as backstop) so the warm path can never be
          worse than re-planning;
        * results are memoized by quantized rate vector (bucket width =
          ``tolerance``): a diurnal control loop revisits its rate buckets
          every period, so steady-state replans are a dict lookup.

        The result carries ``version = prev.version + 1`` and per-module
        ``provenance`` ("reused" | "repaired" | "cached" | "cold");
        ``prev.diff(new)`` yields the hot-swap delta.

        ``force`` names modules that must be re-solved even when their rate
        sits within tolerance — the control plane passes the modules whose
        *profiles* were just corrected toward measured durations, since a
        rate-drift test alone would happily reuse an allocation sized under
        the stale durations.
        """
        with nullcontext() if self.options.vectorized else wcl_memo():
            return self._replan_impl(
                prev, new_rates, profiles, tolerance=tolerance,
                cost_guard=cost_guard, force=frozenset(force),
            )

    def _replan_impl(
        self,
        prev: Plan,
        new_rates: Mapping[str, float],
        profiles: Mapping[str, ModuleProfile],
        *,
        tolerance: float,
        cost_guard: float,
        force: frozenset,
    ) -> Plan:
        t0 = time.perf_counter()
        o = self.options
        wl = replace(
            prev.workload,
            rates=dict(new_rates),
            tag=f"{prev.workload.app.name}@replan-v{prev.version + 1}",
        )

        key = self._cache_key(wl, tolerance, profiles)
        hit = self._replan_cache.get(key)
        if hit is not None and all(
            float(new_rates[m])
            <= collect_capacity(list(hit.schedules[m].allocs)) + _EPS
            for m in wl.app.modules
        ):
            return replace(
                hit,
                workload=wl,
                version=prev.version + 1,
                provenance={m: "cached" for m in wl.app.modules},
                runtime_s=time.perf_counter() - t0,
            )

        def _memo(p: Plan) -> Plan:
            if p.feasible:
                if len(self._replan_cache) >= self._cache_size:
                    self._replan_cache.pop(next(iter(self._replan_cache)))
                self._replan_cache[key] = p
            return p

        def _restamp(p: Plan) -> Plan:
            return replace(
                p,
                version=prev.version + 1,
                provenance={m: "cold" for m in wl.app.modules},
                runtime_s=time.perf_counter() - t0,
            )

        restricted = self._profiles(profiles)

        def single_split() -> Plan:
            # cheap cold tier: one pass of the configured split (it re-derives
            # the budgets, which is the one thing warm repair keeps stale)
            if restricted is None:
                return _restamp(Plan(wl, o, {}, False, 0.0))
            return _restamp(
                self._plan_with_split(wl, restricted, o.split, time.perf_counter())
            )

        def cold() -> Plan:
            p = single_split()
            if not p.feasible:
                p = _restamp(self.plan(wl, profiles))
            return p

        if not prev.feasible:
            return _memo(cold())
        if restricted is None:
            return _memo(cold())
        schedules: dict[str, ModuleSchedule] = {}
        actions: dict[str, str] = {}
        for m in wl.app.modules:
            s_prev = prev.schedules[m]
            r1 = float(new_rates[m])
            drift = abs(r1 - s_prev.rate)
            if (
                m not in force
                and drift <= tolerance * max(s_prev.rate, _EPS)
                and r1 <= collect_capacity(list(s_prev.allocs)) + _EPS
            ):
                schedules[m] = s_prev
                actions[m] = "reused"
                continue
            s = schedule_module(
                m,
                r1,
                s_prev.budget,
                restricted[m],
                o.policy,
                use_dummy=o.use_dummy and o.k_tuples is None,
                k_tuples=o.k_tuples,
                headroom=o.headroom,
                burst=self._burst_of(wl, schedules, m),
                vectorized=o.vectorized,
            )
            if s is None:
                return _memo(cold())
            schedules[m] = s
            actions[m] = "repaired"
        # short reassign pass: hand any e2e slack the rate change opened to
        # residuals (bounded — the full budget search belongs to plan())
        if o.reassign > 0 and o.k_tuples is None and "repaired" in actions.values():
            self._reassign(wl, restricted, schedules, max_iters=8)
        e2e = wl.app.latency({m: s.wcl for m, s in schedules.items()})
        if e2e > wl.slo + 1e-6:
            return _memo(cold())
        warm = Plan(
            wl,
            o,
            schedules,
            True,
            time.perf_counter() - t0,
            version=prev.version + 1,
            provenance=actions,
        )
        # cost-regression guard: frame-rate proportionality says a module's
        # cost scales ~linearly with its rate under a fixed budget, so a warm
        # plan pricier than the per-module-scaled previous cost by more than
        # the guard means the kept budgets went stale — re-derive them
        expected = 0.0
        for m in wl.app.modules:
            s_prev = prev.schedules[m]
            ratio = float(new_rates[m]) / max(s_prev.rate, _EPS)
            expected += s_prev.cost * (ratio if actions[m] != "reused" else 1.0)
        if warm.cost > expected * (1.0 + cost_guard):
            # escalate through the cold tiers until the regression clears:
            # the single-split pass usually recovers the budgets; the full
            # cascade is the backstop when the configured split itself is
            # what went stale (its extra cost is paid only on these epochs)
            best = warm
            for maker in (single_split, lambda: _restamp(self.plan(wl, profiles))):
                fb = maker()
                if fb.feasible and fb.cost < best.cost - 1e-12:
                    best = fb
                if best.cost <= expected * (1.0 + cost_guard):
                    break
            return _memo(best)
        return _memo(warm)


def plan(wl: Workload, profiles: Mapping[str, ModuleProfile], options: PlannerOptions | None = None) -> Plan:
    return Planner(options).plan(wl, profiles)
