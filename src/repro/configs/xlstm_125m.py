"""xlstm-125m [ssm] — sLSTM + mLSTM blocks, no FFN [arXiv:2405.04517]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    arch_type="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,  # blocks carry their own up/down projections
    vocab_size=50304,
    source="arXiv:2405.04517",
    slstm_every=6,  # [mLSTM x5, sLSTM] x2
    ssm_expand=2,
    max_seq_len=1_048_576,  # recurrent state: unbounded context
)

SMOKE = CONFIG.replace(
    n_layers=6,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    vocab_size=512,
    param_dtype="float32",
    compute_dtype="float32",
)
