"""Synthetic profile library + the 1131-workload suite (paper Sec. IV-A).

The paper profiles each module offline on a heterogeneous pool (P100/V100)
and synthesizes 1131 workloads of the five apps from public video streams.
We reproduce the *structure*: every module gets a Table-I-shaped profile
(duration affine in batch size => concave throughput) on a three-tier TPU
hardware catalog with per-module hardware affinities, and workloads sweep a
(rate x SLO) grid per app, truncated to exactly 1131.

Everything is deterministic under ``seed``.
"""
from __future__ import annotations

import random
from typing import Mapping

from ..core.dag import Workload
from ..core.profiles import Config, HARDWARE_CATALOG, ModuleProfile
from .apps import APPS, make_workload

BATCHES = (1, 2, 4, 8, 16, 32, 64)

# plausible per-module compute scales (seconds at batch 1 on tpu-v5e);
# Table-I-scale durations (0.05-0.8 s) so the latency budget actually binds
_MODULE_SCALE = {
    "ssd_detect": 0.25,
    "vehicle_cls": 0.06,
    "pedestrian_cls": 0.07,
    "face_detect": 0.18,
    "prnet_align": 0.14,
    "person_detect": 0.22,
    "openpose": 0.40,
    "frame_prep": 0.04,
    "s2vt_encode": 0.30,
    "s2vt_decode": 0.45,
    "act_detect": 0.28,
    "act_track": 0.10,
    "act_reid": 0.12,
    "action_cls": 0.15,
}


def synth_profiles(seed: int = 0) -> dict[str, ModuleProfile]:
    """One Table-I-shaped profile per module over the TPU catalog."""
    rng = random.Random(seed)
    profiles: dict[str, ModuleProfile] = {}
    for mod, scale in _MODULE_SCALE.items():
        # duration(b) = alpha + beta * b   (fixed overhead + per-item time)
        alpha = scale * rng.uniform(0.6, 1.4)
        beta = scale * rng.uniform(0.15, 0.45)
        # per-hardware speed factor: v5p is 1.3-2.4x faster but 1.75x pricier,
        # v4 is 0.95-1.45x the v5e speed at 1.35x the price => the best
        # throughput-cost hardware is module dependent, as in the paper.
        speed = {
            "tpu-v5e": 1.0,
            "tpu-v4": rng.uniform(0.95, 1.45),
            "tpu-v5p": rng.uniform(1.3, 2.4),
        }
        cfgs = []
        for hw in HARDWARE_CATALOG:
            s = speed[hw.name]
            for b in BATCHES:
                d = (alpha + beta * b) / s
                cfgs.append(Config(b, round(d, 6), hw.name, hw.unit_price))
        profiles[mod] = ModuleProfile(mod, tuple(cfgs))
    return profiles


def synth_workloads(n: int = 1131, seed: int = 0) -> list[Workload]:
    """Exactly ``n`` workloads sweeping (app x rate x SLO)."""
    rng = random.Random(seed + 1)
    rates = [round(10 * 1.26 ** i, 1) for i in range(24)]  # 10 .. ~2.1k req/s
    slos = [0.4, 0.5, 0.6, 0.8, 1.0, 1.2, 1.5, 2.0, 2.5, 3.0]
    out: list[Workload] = []
    combos = [
        (app, r, s) for app in APPS for r in rates for s in slos
    ]  # 5 * 24 * 10 = 1200
    rng.shuffle(combos)
    for app, r, s in combos:
        # mild jitter so rates are not exact multiples of profile throughputs
        rate = r * rng.uniform(0.92, 1.08)
        out.append(make_workload(app, round(rate, 2), s))
        if len(out) >= n:
            break
    return out
