"""Kahn toposort (`core.dag.topo_sort`): deep/wide DAGs, determinism, cycles."""
import random

import pytest

from repro.core.dag import AppDAG, Leaf, par, series, topo_sort


def _assert_topological(order, nodes, edges):
    assert sorted(order) == sorted(nodes)
    pos = {m: i for i, m in enumerate(order)}
    for u, v in edges:
        assert pos[u] < pos[v], (u, v)


def test_deep_chain():
    n = 500
    nodes = [f"m{i}" for i in range(n)]
    edges = [(f"m{i}", f"m{i+1}") for i in range(n - 1)]
    shuffled = nodes[:]
    random.Random(0).shuffle(shuffled)
    _assert_topological(topo_sort(shuffled, edges), nodes, edges)


def test_wide_diamond_deterministic():
    mid = [f"p{i}" for i in range(300)]
    nodes = ["src"] + mid + ["sink"]
    edges = [("src", p) for p in mid] + [(p, "sink") for p in mid]
    order = topo_sort(nodes, edges)
    _assert_topological(order, nodes, edges)
    # among simultaneously-ready nodes, input order is preserved
    assert order == nodes
    assert topo_sort(nodes, edges) == order


def test_random_layered_dag():
    rng = random.Random(7)
    layers = [[f"l{d}_{i}" for i in range(rng.randint(2, 8))] for d in range(12)]
    nodes = [m for layer in layers for m in layer]
    edges = []
    for a, b in zip(layers, layers[1:]):
        for v in b:
            for u in rng.sample(a, k=rng.randint(1, len(a))):
                edges.append((u, v))
    shuffled = nodes[:]
    rng.shuffle(shuffled)
    _assert_topological(topo_sort(shuffled, edges), nodes, edges)


def test_cycle_detection():
    with pytest.raises(ValueError, match="cycle"):
        topo_sort(["a", "b", "c"], [("a", "b"), ("b", "c"), ("c", "a")])
    with pytest.raises(ValueError, match="cycle"):
        topo_sort(["a"], [("a", "a")])
    # cycle hanging off an acyclic prefix
    with pytest.raises(ValueError, match="cycle"):
        topo_sort(["a", "b", "c"], [("a", "b"), ("b", "c"), ("c", "b")])


def test_unknown_node_in_edge():
    with pytest.raises(ValueError, match="unknown"):
        topo_sort(["a"], [("a", "zz")])


def test_appdag_topo_order():
    app = AppDAG("t", series(Leaf("a"), par(Leaf("b"), Leaf("c")), Leaf("d")))
    order = app.topo_order()
    _assert_topological(order, app.modules, app.edges)


# ---------------------- iterative SP latency program (ISSUE-10 satellite)


def _random_sp(rng, depth, counter):
    """A random series/parallel tree over fresh leaf names."""
    if depth == 0 or rng.random() < 0.3:
        counter[0] += 1
        return Leaf(f"n{counter[0]}")
    parts = [
        _random_sp(rng, depth - 1, counter)
        for _ in range(rng.randint(2, 4))
    ]
    return series(*parts) if rng.random() < 0.5 else par(*parts)


def test_latency_program_bit_equal_to_recursion():
    """`AppDAG.latency` (iterative postorder program) is BIT-equal to the
    `sp_latency` recursion on random SP trees and random float weights —
    same IEEE-754 operations in the same order, pinned."""
    from repro.core.dag import compile_sp, sp_latency, sp_latency_program

    rng = random.Random(42)
    for trial in range(50):
        counter = [0]
        sp = _random_sp(rng, depth=rng.randint(1, 5), counter=counter)
        app = AppDAG(f"t{trial}", sp)
        w = {m: rng.uniform(1e-6, 10.0) for m in app.modules}
        ref = sp_latency(sp, w)
        assert app.latency(w) == ref  # exact, not approx
        assert sp_latency_program(compile_sp(sp), w) == ref


def test_latency_program_single_leaf_and_callable_weights():
    from repro.core.dag import sp_latency

    app = AppDAG("one", series(Leaf("only")))
    assert app.latency({"only": 0.1}) == sp_latency(app.sp, {"only": 0.1})
    nested = AppDAG(
        "n", series(Leaf("a"), par(series(Leaf("b"), Leaf("c")), Leaf("d")))
    )
    w = {"a": 0.3, "b": 0.7, "c": 0.2, "d": 1.1}
    assert nested.latency(w.__getitem__) == sp_latency(nested.sp, w.__getitem__)
