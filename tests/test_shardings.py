"""Sharding rules + an 8-device end-to-end sharded train/decode (subprocess)."""
import subprocess
import sys
import textwrap

import jax
import pytest

# JAX-compile-heavy (jits real kernels/models); deselect with -m "not slow"
pytestmark = pytest.mark.slow

from repro.configs import ARCHS, SHAPES
from repro.launch.shardings import divisibility_fix, param_spec
from repro.models import Model
from jax.sharding import PartitionSpec as P


def test_param_spec_rules():
    cfg = ARCHS["deepseek-v3-671b"]

    class L:  # fake leaf
        def __init__(self, shape):
            self.shape = shape
            self.ndim = len(shape)

    # stacked expert weights: EP on the expert dim (-3), not the repeats dim
    spec = param_spec(
        "segments/1/0/ffn/w1", L((58, 256, 7168, 2048)), cfg,
        ep_axes=("data", "model"), fsdp=False, ep=256,
    )
    assert spec == P(None, ("data", "model"), None, None)
    # attention projections: column-parallel
    spec = param_spec("segments/0/0/mix/q_b/w", L((58, 1536, 24576)), cfg,
                      ep_axes=(), fsdp=False)
    assert spec[-1] == "model"
    # norms replicated
    spec = param_spec("segments/0/0/mix_norm/w", L((58, 7168)), cfg,
                      ep_axes=(), fsdp=False)
    assert all(e is None for e in spec)


def test_divisibility_fix():
    class L:
        shape = (2, 8)
        ndim = 2

    fixed = divisibility_fix(P(None, "model"), L(), {"model": 16})
    assert fixed == P(None, None)
    fixed = divisibility_fix(P(None, "model"), L(), {"model": 8})
    assert fixed == P(None, "model")


_SHARDED_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.configs import SMOKE_ARCHS
    from repro.models import Model
    from repro.launch.shardings import param_specs, to_shardings
    from repro.training import OptConfig, adamw_init, make_train_step
    from repro.data import lm_batches

    cfg = SMOKE_ARCHS["smollm-360m"]
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    specs = param_specs(jax.eval_shape(lambda: params), cfg, mesh=mesh)
    shardings = to_shardings(specs, mesh)
    params = jax.device_put(params, shardings)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(model, OptConfig(lr=1e-3, total_steps=4)))
    batch = next(lm_batches(cfg.vocab_size, 4, 16))
    with mesh:
        batch = jax.device_put(batch, NamedSharding(mesh, P("data", None)))
        l0 = None
        for i in range(4):
            params, opt, metrics = step(params, opt, batch)
            if l0 is None:
                l0 = float(metrics["loss"])
        l1 = float(metrics["loss"])
    assert l1 < l0, (l0, l1)
    print("SHARDED-TRAIN-OK", l0, "->", l1)

    # sharded decode consistency vs single-device forward
    toks = jax.random.randint(jax.random.key(1), (4, 10), 0, cfg.vocab_size)
    full = model.forward(params, toks)
    cache = model.init_cache(4, 16)
    pre = model.forward(params, toks[:, :9], cache=cache, idx=0)
    dec = model.forward(params, toks[:, 9:], cache=pre.cache, idx=9)
    err = float(jnp.max(jnp.abs(full.logits[:, -1] - dec.logits[:, 0])))
    rel = err / (float(jnp.max(jnp.abs(full.logits[:, -1]))) + 1e-9)
    assert rel < 2e-3, rel
    print("SHARDED-DECODE-OK", rel)
    """
)


def test_sharded_train_and_decode_8_devices():
    r = subprocess.run(
        [sys.executable, "-c", _SHARDED_SCRIPT],
        capture_output=True,
        text=True,
        timeout=560,
        cwd=".",
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "SHARDED-TRAIN-OK" in r.stdout
    assert "SHARDED-DECODE-OK" in r.stdout
