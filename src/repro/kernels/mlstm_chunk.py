"""Pallas TPU chunkwise-parallel mLSTM (xLSTM matrix-memory cell).

TPU adaptation of the recurrent matrix-memory update: the sequence is split
into chunks; within a chunk the stabilized exponential-gating attention runs
in parallel (two (C, C) / (C, D) matmuls — MXU work), while the (D, D)
matrix state, the (D,) normalizer and the scalar stabilizer are carried
across chunks in VMEM scratch.  Grid = (B * H, L / C) with the chunk axis
sequential.

Oracle: `repro.kernels.ref.mlstm_chunked` (full-parallel stabilized form).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    q_ref,  # (1, C, D)
    k_ref,
    v_ref,
    li_ref,  # (1, C) log input gate
    lf_ref,  # (1, C) log forget gate (log-sigmoid applied)
    o_ref,  # (1, C, D)
    state_ref,  # VMEM (D, D) f32
    n_ref,  # VMEM (1, D) f32
    m_ref,  # VMEM (1, 1) f32
    *,
    c: int,
    scale: float,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[:] = jnp.zeros_like(state_ref)
        n_ref[:] = jnp.zeros_like(n_ref)
        m_ref[:] = jnp.full_like(m_ref, NEG_INF)

    q = q_ref[0].astype(jnp.float32) * scale  # (C, D)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    li = li_ref[0].astype(jnp.float32)  # (C,)
    lf = lf_ref[0].astype(jnp.float32)
    m_in = m_ref[0, 0]
    C_in = state_ref[:]
    n_in = n_ref[0]

    F = jnp.cumsum(lf)  # (C,) cumulative log forget within chunk
    # stabilizer per step: max(inter, intra)
    #   inter_t = m_in + F_t;   intra_t = max_{s<=t}(F_t - F_s + i_s)
    logw = F[:, None] - F[None, :] + li[None, :]  # (C, C) log intra weights
    tri = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1) <= jax.lax.broadcasted_iota(
        jnp.int32, (c, c), 0
    )
    logw = jnp.where(tri, logw, NEG_INF)
    intra_max = jnp.max(logw, axis=1)  # (C,)
    m_t = jnp.maximum(m_in + F, intra_max)  # (C,)

    w = jnp.exp(logw - m_t[:, None])  # (C, C)
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (C, C)
    inter_scale = jnp.exp(m_in + F - m_t)  # (C,)
    qC = jax.lax.dot_general(
        q, C_in, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (C, D)
    num = inter_scale[:, None] * qC + jax.lax.dot_general(
        scores * w, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    qn = jnp.sum(q * n_in[None, :], axis=1)  # (C,)
    den = inter_scale * qn + jnp.sum(scores * w, axis=1)
    den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
    o_ref[0] = (num / den[:, None]).astype(o_ref.dtype)

    # ---- carry the state across the chunk boundary
    F_C = F[-1]
    decay = F_C - F + li  # (C,) log weight of step s in the outgoing state
    m_out = jnp.maximum(m_in + F_C, jnp.max(decay))
    w_out = jnp.exp(decay - m_out)  # (C,)
    kw = k * w_out[:, None]  # (C, D)
    state_ref[:] = jnp.exp(m_in + F_C - m_out) * C_in + jax.lax.dot_general(
        kw, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    n_ref[0] = jnp.exp(m_in + F_C - m_out) * n_in + jnp.sum(kw, axis=0)
    m_ref[0, 0] = m_out


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def chunked_mlstm(
    q: jax.Array,  # (B, L, H, D)
    k: jax.Array,
    v: jax.Array,
    i_gate: jax.Array,  # (B, L, H) log input gate
    f_gate: jax.Array,  # (B, L, H) log forget gate
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, L, H, D = q.shape
    c = min(chunk, L)
    assert L % c == 0, (L, c)
    nc = L // c
    scale = D ** -0.5

    tr = lambda x: x.transpose(0, 2, 1, 3).reshape(B * H, L, D)
    trg = lambda g: g.transpose(0, 2, 1).reshape(B * H, L)

    out = pl.pallas_call(
        functools.partial(_kernel, c=c, scale=scale),
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, c, D), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, c, D), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, c, D), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, c), lambda bh, ci: (bh, ci)),
            pl.BlockSpec((1, c), lambda bh, ci: (bh, ci)),
        ],
        out_specs=pl.BlockSpec((1, c, D), lambda bh, ci: (bh, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, L, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((D, D), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(tr(q), tr(k), tr(v), trg(i_gate), trg(f_gate))
    return out.reshape(B, H, L, D).transpose(0, 2, 1, 3)
