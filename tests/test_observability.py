"""Serving observability layer (ISSUE-7): structured tracing, the metrics
registry, and SLO-miss forensics.

Pins the layer's load-bearing properties: results are BIT-identical with
observability on, off, or sampled (flat, pipelined, and control-plane
paths); every miss report conserves — cause counts sum exactly to
``offered - completed-in-SLO`` — across apps x arrivals x admission x
control epochs, with each miss carrying exactly one cause; the Perfetto
export is valid trace-event JSON; the trace ring buffer and deterministic
sampling behave as documented; the `relax` chain on/off is
bit-identical under burst deadlines (the PR-6 inertness finding the
rename records); and the BENCH_serving.json writer merges by name into a
deterministic, schema-versioned document.
"""
import json
import os
import sys

import numpy as np
import pytest

from repro.core import Planner
from repro.core import baselines as B
from repro.serving import (
    MISS_CAUSES,
    ControlLoopConfig,
    FrontendConfig,
    ObservabilityConfig,
    QueueDepth,
    ServingEngine,
    TokenBucket,
    TraceRecorder,
)
from repro.serving.arrivals import trace_arrivals
from repro.workloads import synth_profiles
from repro.workloads.apps import app_by_name, make_workload

PROFILES = synth_profiles()

_PLANS: dict = {}


def suite_plan(name, rate, slo):
    key = (name, rate, slo)
    if key not in _PLANS:
        plan = Planner(B.HARPAGON).plan(
            make_workload(app_by_name(name), rate, slo), PROFILES
        )
        assert plan.feasible
        _PLANS[key] = plan
    return _PLANS[key]


def result_key(res):
    """Everything a run computes, hashable — the bit-exactness fingerprint."""
    key = [
        tuple(res.e2e_latencies), res.shed, res.dropped, res.attempts,
        tuple(sorted(
            (m, s.batches, s.dropped, s.phantom, tuple(s.latencies))
            for m, s in res.module_stats.items()
        )),
    ]
    if res.pipeline is not None:
        pr = res.pipeline
        key.append(pr.e2e.tobytes())
        key.extend(pr.finish[m].tobytes() for m in pr.modules)
        key.append(pr.shed.tobytes())
        key.append(pr.dropped.tobytes())
    return tuple(key)


ADMISSIONS = {
    "none": None,
    "token_bucket": TokenBucket(burst=4),
    "queue_depth": QueueDepth(depth=8),
}


# ------------------------------------------------ bit-exactness, all paths


class TestBitExact:
    def test_pipeline_on_off_sampled(self):
        plan = suite_plan("face", 150.0, 2.5)
        eng = ServingEngine(plan)
        kw = dict(
            arrivals="mmpp", seed=0, offered_rate=1.3 * 150.0,
            frontend=FrontendConfig(admission=TokenBucket(burst=4)),
            pipeline=True,
        )
        off = eng.run(800, 150.0, **kw)
        on = eng.run(800, 150.0, observability=True, **kw)
        sampled = eng.run(
            800, 150.0,
            observability=ObservabilityConfig(sample=0.1, capacity=512), **kw
        )
        assert result_key(off) == result_key(on) == result_key(sampled)
        assert off.metrics is None and off.trace is None
        assert on.metrics is not None and on.trace is not None

    def test_flat_on_off(self):
        plan = suite_plan("face", 150.0, 2.5)
        eng = ServingEngine(plan)
        kw = dict(
            arrivals="mmpp", seed=0, offered_rate=1.3 * 150.0,
            frontend=FrontendConfig(admission=QueueDepth(depth=8)),
        )
        off = eng.run(800, 150.0, **kw)
        on = eng.run(800, 150.0, observability=True, **kw)
        assert result_key(off) == result_key(on)
        # flat-path ingress sheds reach the telemetry (admission.obs hook)
        assert on.shed > 0
        assert sum(
            1 for ev in on.trace.events() if ev[4] == "shed"
        ) == on.shed

    def test_control_plane_on_off(self):
        plan = suite_plan("face", 150.0, 2.5)
        eng = ServingEngine(plan)
        n, rate = 1200, 150.0
        period = n / rate
        arr = trace_arrivals(n, rate, seed=0, period=period)
        kw = dict(
            arrivals=arr, timeout="budget",
            frontend=FrontendConfig(dummies=True, burst_deadline=True),
            pipeline=True,
            control=ControlLoopConfig(
                interval=period / 4, profiles=PROFILES, margin=0.25
            ),
        )
        off = eng.run(n, rate, **kw)
        on = eng.run(n, rate, observability=True, **kw)
        assert result_key(off) == result_key(on)
        # one metrics window per epoch boundary + the final flush
        assert on.metrics is not None and len(on.metrics.rows) > 0

    def test_fastpath_reports_column_metrics(self):
        # a plain open-loop run stays fast-path eligible with tracing on:
        # the telemetry is column-level (bulk batch/busy tallies), not
        # per-event spans, and results remain bit-exact
        plan = suite_plan("traffic", 100.0, 2.0)
        eng = ServingEngine(plan)
        off = eng.run(2000, 100.0, pipeline=True)
        on = eng.run(2000, 100.0, pipeline=True, observability=True)
        assert result_key(off) == result_key(on)
        rows = on.metrics.rows
        assert rows and sum(r["batches"] for r in rows) == sum(
            s.batches for s in on.module_stats.values()
        )


# ----------------------------------------- miss-cause conservation matrix


class TestConservation:
    @pytest.mark.parametrize("app,rate,slo", [
        ("face", 150.0, 2.5), ("traffic", 100.0, 2.0),
    ])
    @pytest.mark.parametrize("arrivals", ["uniform", "mmpp"])
    @pytest.mark.parametrize("admission", list(ADMISSIONS))
    @pytest.mark.parametrize("control", [False, True])
    def test_conserves(self, app, rate, slo, arrivals, admission, control):
        plan = suite_plan(app, rate, slo)
        eng = ServingEngine(plan)
        n = 400
        ctrl = (
            ControlLoopConfig(interval=n / rate / 3, profiles=PROFILES)
            if control
            else None
        )
        res = eng.run(
            n, rate, arrivals=arrivals, seed=0, timeout="budget",
            frontend=FrontendConfig(
                dummies=True, admission=ADMISSIONS[admission]
            ),
            offered_rate=1.3 * rate, pipeline=True, control=ctrl,
        )
        rep = res.miss_report()
        assert rep.conserved
        assert set(rep.counts) <= set(MISS_CAUSES)
        # exactly one cause per miss, no cause on non-misses
        n_caused = int((rep.cause_of >= 0).sum())
        assert n_caused == rep.total == sum(rep.counts.values())
        assert rep.offered - rep.completed_in_slo == rep.total

    def test_shed_frames_are_admission_shed(self):
        plan = suite_plan("face", 150.0, 2.5)
        res = ServingEngine(plan).run(
            600, 150.0, arrivals="mmpp", seed=0,
            frontend=FrontendConfig(admission=TokenBucket(burst=4)),
            offered_rate=1.5 * 150.0, pipeline=True,
        )
        rep = res.miss_report()
        n_shed = int(res.pipeline.shed.sum())
        assert n_shed > 0
        assert rep.counts.get("admission_shed", 0) == n_shed
        assert rep.conserved

    def test_miss_report_requires_pipeline(self):
        plan = suite_plan("face", 150.0, 2.5)
        res = ServingEngine(plan).run(200, 150.0)
        with pytest.raises(ValueError, match="pipeline"):
            res.miss_report()


# ------------------------------------------------ trace recorder mechanics


class TestTraceRecorder:
    def test_ring_buffer_overwrites_and_counts_drops(self):
        tr = TraceRecorder(capacity=4)
        for i in range(10):
            tr.instant(float(i), "m", 0, f"e{i}")
        evs = tr.events()
        assert len(evs) == 4
        assert [e[1] for e in evs] == [6.0, 7.0, 8.0, 9.0]  # oldest evicted
        assert tr.dropped == 6

    def test_deterministic_stride_sampling(self):
        tr = TraceRecorder(sample=0.5)
        hits = [tr.sampled() for _ in range(10)]
        assert hits == [True, False] * 5
        assert TraceRecorder(sample=1.0).stride == 1
        assert TraceRecorder(sample=0.1).stride == 10

    def test_validation(self):
        with pytest.raises(ValueError):
            TraceRecorder(capacity=0)
        with pytest.raises(ValueError):
            TraceRecorder(sample=0.0)
        with pytest.raises(ValueError):
            ObservabilityConfig(sample=2.0)

    def test_chrome_export_is_loadable(self, tmp_path):
        plan = suite_plan("face", 150.0, 2.5)
        n, rate = 900, 150.0
        period = n / rate
        res = ServingEngine(plan).run(
            n, rate,
            arrivals=trace_arrivals(n, rate, seed=0, period=period),
            timeout="budget",
            frontend=FrontendConfig(dummies=True, burst_deadline=True),
            pipeline=True,
            control=ControlLoopConfig(
                interval=period / 3, profiles=PROFILES, margin=0.25
            ),
            observability=True,
        )
        path = res.trace.export(str(tmp_path / "trace.json"))
        doc = json.loads(open(path).read())
        evs = doc["traceEvents"]
        assert evs
        assert doc["displayTimeUnit"] == "ms"
        phs = {e["ph"] for e in evs}
        assert phs <= {"X", "i", "C", "M"}
        assert "X" in phs and "M" in phs  # spans + process metadata
        for e in evs:
            assert isinstance(e["pid"], int) and isinstance(e["name"], str)
            if e["ph"] == "X":
                assert e["dur"] >= 0 and e["ts"] >= 0
        # an epoch instant per control epoch (always recorded, never sampled)
        n_epoch = sum(1 for e in evs if e["ph"] == "i" and e["name"] == "epoch")
        assert n_epoch == len(res.epochs) - 1  # history[0] predates the loop


# --------------------------------------------------------- metrics sanity


class TestMetrics:
    def test_rows_are_sane(self):
        plan = suite_plan("face", 150.0, 2.5)
        res = ServingEngine(plan).run(
            800, 150.0, arrivals="mmpp", seed=0, timeout="budget",
            frontend=FrontendConfig(
                dummies=True, admission=TokenBucket(burst=4)
            ),
            offered_rate=1.3 * 150.0, pipeline=True, observability=True,
        )
        rows = [r for r in res.metrics.rows if r["module"] != "(ingress)"]
        assert rows
        for r in rows:
            assert 0.0 < r["occupancy"] <= 1.0
            assert 0.0 <= r["dummy_fill"] <= 1.0
            assert r["utilization"] >= 0.0
            assert r["t1"] > r["t0"]
            assert sum(r["closes"].values()) >= 0
        assert sum(r["batches"] for r in rows) == sum(
            s.batches for s in res.module_stats.values()
        )
        table = res.metrics.table()
        assert "occupancy" in table and "utilization" in table
        assert res.metrics.for_module(rows[0]["module"])


# ----------------------------- relax: scoped inertness (PR-6, promoted PR-8)


class TestExperimentalRelax:
    """The PR-6 finding, re-measured with this layer's forensics.

    PR-6 recorded the relax chain as inert everywhere.  The miss
    forensics show the true scope: on STEADY arrival regimes the
    observed rate never falls below the provisioned target, the tick
    never fires, and runs are bit-identical relax on/off — but on
    diurnal traces stale coarse plans DO deadline-flush near-empty
    padded batches, relaxation retimes those flushes, and the
    ``flush_waste`` miss count drops.  Both halves are pinned here.
    """

    @pytest.mark.parametrize("arrivals", ["uniform", "poisson"])
    def test_steady_regimes_bit_identical(self, arrivals):
        plan = suite_plan("face", 150.0, 2.5)
        eng = ServingEngine(plan)
        n, rate = 1200, 150.0

        def run(relax):
            return eng.run(
                n, rate, arrivals=arrivals, seed=0, timeout="budget",
                frontend=FrontendConfig(dummies=True, burst_deadline=True),
                pipeline=True,
                control=ControlLoopConfig(
                    interval=n / rate / 4, profiles=PROFILES, margin=0.25,
                    relax=relax,
                ),
            )

        assert result_key(run(True)) == result_key(run(False))

    def test_diurnal_relax_fires_and_cuts_flush_waste(self):
        plan = suite_plan("face", 150.0, 2.5)
        eng = ServingEngine(plan)
        n, rate = 1200, 150.0
        period = n / rate
        arr = trace_arrivals(n, rate, seed=0, period=period)

        def run(relax):
            return eng.run(
                n, rate, arrivals=arr, timeout="budget",
                frontend=FrontendConfig(dummies=True, burst_deadline=True),
                pipeline=True,
                control=ControlLoopConfig(
                    interval=period / 4, profiles=PROFILES, margin=0.25,
                    relax=relax,
                ),
            ).miss_report()

        on, off = run(True), run(False)
        assert on.conserved and off.conserved
        assert on.counts.get("flush_waste", 0) < off.counts.get(
            "flush_waste", 0
        )

    def test_knob_validation(self):
        with pytest.raises(ValueError, match="relax_floor"):
            ControlLoopConfig(interval=1.0, relax_floor=0.0)
        with pytest.raises(ValueError, match="relax_every"):
            ControlLoopConfig(interval=1.0, relax_every=0.0)

    def test_deprecated_aliases_removed(self):
        # The experimental_relax* aliases served their one-release
        # deprecation window (promoted in PR 8, dropped in PR 9): passing
        # them must now fail loudly instead of silently mapping.
        for kw in (
            "experimental_relax",
            "experimental_relax_tol",
            "experimental_relax_floor",
            "experimental_relax_every",
        ):
            with pytest.raises(TypeError):
                ControlLoopConfig(interval=1.0, **{kw: 0.2})
        assert not hasattr(ControlLoopConfig(interval=1.0), "experimental_relax")


# ------------------------------------------- BENCH_serving.json merge-write


class TestBenchJson:
    @staticmethod
    def _common():
        sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
        from benchmarks import common
        return common

    def test_merge_sorted_versioned_deterministic(self, tmp_path):
        common = self._common()
        path = str(tmp_path / "bench.json")
        common.write_bench_json(
            path,
            [{"name": "b_row", "us_per_call": 1.0, "derived": "x"},
             {"name": "a_row", "us_per_call": 2.0, "derived": "y"}],
        )
        doc = json.loads(open(path).read())
        assert doc["schema_version"] == common.SCHEMA_VERSION
        assert [r["name"] for r in doc["benches"]] == ["a_row", "b_row"]
        # partial re-run: update one row, add one — others preserved
        common.write_bench_json(
            path,
            [{"name": "b_row", "us_per_call": 9.0, "derived": "x2"},
             {"name": "c_row", "us_per_call": 3.0, "derived": "z"}],
        )
        doc = json.loads(open(path).read())
        assert [r["name"] for r in doc["benches"]] == [
            "a_row", "b_row", "c_row"
        ]
        assert doc["benches"][1]["us_per_call"] == 9.0
        # idempotent: same rows -> same bytes
        before = open(path).read()
        common.write_bench_json(
            path, [{"name": "c_row", "us_per_call": 3.0, "derived": "z"}]
        )
        assert open(path).read() == before

    def test_corrupt_file_is_replaced_not_fatal(self, tmp_path):
        common = self._common()
        path = str(tmp_path / "bench.json")
        open(path, "w").write("{not json")
        common.write_bench_json(
            path, [{"name": "a", "us_per_call": 1.0, "derived": "d"}]
        )
        doc = json.loads(open(path).read())
        assert [r["name"] for r in doc["benches"]] == ["a"]
