from .analytic import (
    arch_profile,
    flops_per_token,
    kv_cache_bytes_per_token,
    module_duration,
    param_count,
)
from .hardware import CATALOG, TARGET, TPUSpec
from .interference import InterferenceModel, calibrate as calibrate_interference
from .measured import (
    corrected_profile,
    corrected_profiles,
    duration_scale,
    quantize_scale,
)

__all__ = [
    "CATALOG", "InterferenceModel", "TARGET", "TPUSpec", "arch_profile",
    "calibrate_interference", "corrected_profile", "corrected_profiles",
    "duration_scale", "flops_per_token", "kv_cache_bytes_per_token",
    "module_duration", "param_count", "quantize_scale",
]
