"""Hypothesis property tests on system invariants."""
import math

import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")

from hypothesis import given, settings, strategies as st

from repro.core import Alloc, Policy, generate_config, module_wcl, total_cost
from repro.core.dispatch import config_wcl, dispatch_trace, expand_machines
from repro.core.profiles import Config, ModuleProfile
from repro.core.residual import apply_dummy
from repro.core.scheduler import get_wcl
from repro.serving.simulator import simulate


@st.composite
def profiles(draw):
    n = draw(st.integers(2, 6))
    cfgs = []
    base = draw(st.floats(0.02, 0.5))
    for i in range(n):
        b = 2 ** draw(st.integers(0, 6))
        # duration affine in batch => concave throughput, like real profiles
        beta = draw(st.floats(0.1, 0.9))
        d = base * (1 + beta * b)
        p = draw(st.sampled_from([1.0, 1.35, 1.75]))
        cfgs.append(Config(b, round(d, 6), f"hw{p}", p))
    return ModuleProfile("m", tuple(cfgs))


@given(profiles(), st.floats(1.0, 500.0), st.floats(0.1, 5.0))
@settings(max_examples=60, deadline=None)
def test_scheduler_invariants(profile, T, L):
    ok, allocs = generate_config(T, L, profile, Policy.TC)
    if not ok:
        return
    # exact coverage
    assert math.isclose(sum(a.rate for a in allocs), T, rel_tol=1e-9)
    # every machine within budget
    assert module_wcl(allocs, Policy.TC) <= L + 1e-9
    # allocations ordered by effective ratio descending (greedy walk;
    # dummy-filled residual machines rank last)
    ratios = [a.eff_ratio for a in allocs]
    assert all(r1 >= r2 - 1e-9 for r1, r2 in zip(ratios, ratios[1:]))
    # cost is at least the fractional lower bound T / max ratio
    lb = T / profile.configs[0].ratio
    assert total_cost(allocs) >= lb - 1e-9


@given(profiles(), st.floats(1.0, 500.0), st.floats(0.1, 5.0))
@settings(max_examples=60, deadline=None)
def test_tc_wcl_never_worse_than_rr(profile, T, L):
    ok, allocs = generate_config(T, L, profile, Policy.TC)
    if not ok:
        return
    assert module_wcl(allocs, Policy.TC) <= module_wcl(allocs, Policy.RR) + 1e-9


@given(profiles(), st.floats(1.0, 500.0), st.floats(0.1, 5.0))
@settings(max_examples=40, deadline=None)
def test_dummy_only_reduces_cost(profile, T, L):
    ok, allocs = generate_config(T, L, profile, Policy.TC)
    if not ok:
        return
    base = total_cost(allocs)
    dummy, new_allocs = apply_dummy(T, L, profile, allocs, Policy.TC)
    assert total_cost(new_allocs) <= base + 1e-9
    if dummy > 0:
        assert total_cost(new_allocs) < base - 1e-12
        # dummy-padded schedule still meets the latency budget
        assert module_wcl(new_allocs, Policy.TC) <= L + 1e-9


@given(profiles(), st.floats(5.0, 300.0))
@settings(max_examples=25, deadline=None)
def test_theorem1_bounds_simulation(profile, T):
    """Empirical L_wc <= analytic L_wc + one-batch jitter (fluid-limit gap)."""
    ok, allocs = generate_config(T, 10.0, profile, Policy.TC)
    if not ok or any(a.dummy > 0 for a in allocs):
        return  # the simulator streams real requests only
    theory = module_wcl(allocs, Policy.TC)
    sim = simulate(allocs, T, policy=Policy.TC, n_requests=1200)
    if sim.n_requests == 0:
        return
    jitter = max(a.config.batch for a in allocs) / T
    assert sim.max_latency <= theory + jitter + 1e-6


@given(profiles(), st.integers(50, 400))
@settings(max_examples=25, deadline=None)
def test_tc_trace_is_batched_and_complete(profile, n):
    ok, allocs = generate_config(100.0, 10.0, profile, Policy.TC)
    if not ok or any(a.dummy > 0 for a in allocs):
        return  # dummy-filled plans mix phantom requests into batches
    machines = expand_machines(allocs)
    trace = dispatch_trace(machines, n, Policy.TC)
    # every request assigned exactly once, ids consecutive
    assert [r for r, _ in trace] == list(range(n))
    # consecutive runs per machine have length == its batch (except the tail)
    runs = []
    cur_m, cur_len = None, 0
    for _, mid in trace:
        if mid == cur_m:
            cur_len += 1
        else:
            if cur_m is not None:
                runs.append((cur_m, cur_len))
            cur_m, cur_len = mid, 1
    by_mid = {m.mid: m.config.batch for m in machines}
    for mid, ln in runs[:-1]:
        # a machine may legitimately receive several batches back-to-back
        assert ln % by_mid[mid] == 0


@given(
    st.floats(1.0, 50.0),
    st.integers(1, 64),
    st.floats(0.05, 2.0),
)
@settings(max_examples=50, deadline=None)
def test_wcl_monotone_in_collect_rate(rate, batch, dur):
    c = Config(batch, dur)
    lo = config_wcl(c, Policy.TC, collect_rate=rate)
    hi = config_wcl(c, Policy.TC, collect_rate=rate * 2)
    assert hi <= lo
