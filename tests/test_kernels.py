"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# JAX-compile-heavy (jits real kernels/models); deselect with -m "not slow"
pytestmark = pytest.mark.slow

# The kernels target the Pallas TPU API surface they were written against
# (`pltpu.CompilerParams`); jax builds predating or renaming that surface
# (older builds call it `TPUCompilerParams`) fail every kernel call with an
# AttributeError.  That is an environment capability gap, not a kernel
# regression — skip the whole module rather than fail 30+ parametrizations.
try:
    from jax.experimental.pallas import tpu as _pltpu

    _has_pallas_surface = hasattr(_pltpu, "CompilerParams")
except ImportError:  # pragma: no cover - env-dependent
    _has_pallas_surface = False
if not _has_pallas_surface:
    pytest.skip(
        "Pallas TPU kernel surface (pltpu.CompilerParams) unavailable in "
        "this environment's jax build",
        allow_module_level=True,
    )

from repro.kernels import ref
from repro.kernels.decode_attention import flash_decode
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import fused_rmsnorm
from repro.kernels.ssm_scan import chunked_selective_scan


def rel_err(a, b):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-9)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,Hq,Hkv,D,window",
    [
        (1, 128, 1, 1, 64, None),
        (2, 256, 4, 2, 64, None),
        (2, 256, 4, 1, 128, None),  # MQA
        (1, 384, 6, 2, 128, 128),  # sliding window
        (2, 128, 8, 8, 256, None),  # MHA, gemma head_dim
    ],
)
def test_flash_attention_sweep(B, S, Hq, Hkv, D, window, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    out = flash_attention(q, k, v, causal=True, window=window, interpret=True)
    exp = ref.attention(q, k, v, causal=True, window=window)
    assert rel_err(out, exp) < TOL[dtype]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,Hq,Hkv,Dk,Dv,window",
    [
        (2, 256, 4, 2, 64, 64, None),
        (3, 512, 4, 1, 128, 128, None),  # MQA
        (2, 256, 8, 8, 64, 64, 100),  # window
        (1, 256, 4, 1, 192, 128, None),  # MLA-absorbed: Dk != Dv
    ],
)
def test_flash_decode_sweep(B, S, Hq, Hkv, Dk, Dv, window, dtype):
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (B, Hq, Dk), dtype)
    kc = jax.random.normal(ks[1], (B, S, Hkv, Dk), dtype)
    vc = jax.random.normal(ks[2], (B, S, Hkv, Dv), dtype)
    lengths = jnp.asarray([(S * (i + 1)) // (B + 1) + 1 for i in range(B)], jnp.int32)
    out = flash_decode(q, kc, vc, lengths, window=window, block_k=128, interpret=True)
    exp = ref.decode_attention(q, kc, vc, lengths, window=window)
    assert rel_err(out, exp) < TOL[dtype]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,L,D,N,chunk", [(2, 256, 32, 8, 64), (1, 128, 64, 16, 128)])
def test_selective_scan_sweep(B, L, D, N, chunk, dtype):
    ks = jax.random.split(jax.random.key(2), 5)
    x = (jax.random.normal(ks[0], (B, L, D)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, D))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (D, N)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, L, N)).astype(dtype)
    Cm = jax.random.normal(ks[4], (B, L, N)).astype(dtype)
    h0 = jnp.zeros((B, N, D), jnp.float32)
    y, h = chunked_selective_scan(x, dt, A, Bm, Cm, h0, chunk=chunk, interpret=True)
    y2, h2 = ref.selective_scan(x, dt, A, Bm, Cm, h0)
    assert rel_err(y, y2) < TOL[dtype]
    assert rel_err(h, h2) < TOL[dtype]


def test_selective_scan_carries_state():
    """Scanning two halves with carried state == scanning the whole sequence."""
    B, L, D, N = 1, 128, 16, 8
    ks = jax.random.split(jax.random.key(3), 5)
    x = jax.random.normal(ks[0], (B, L, D)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, D)))
    A = -jnp.exp(jax.random.normal(ks[2], (D, N)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, L, N))
    Cm = jax.random.normal(ks[4], (B, L, N))
    y_full, h_full = ref.selective_scan(x, dt, A, Bm, Cm)
    h = None
    ys = []
    for sl in (slice(0, 64), slice(64, 128)):
        y, h = ref.selective_scan(x[:, sl], dt[:, sl], A, Bm[:, sl], Cm[:, sl], h)
        ys.append(y)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(ys, 1)), np.asarray(y_full), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape,gemma", [((4, 7, 128), False), ((2, 256), True), ((3, 3, 3, 256), False)])
def test_rmsnorm_sweep(shape, gemma, dtype):
    x = jax.random.normal(jax.random.key(4), shape, dtype)
    w = jax.random.normal(jax.random.key(5), (shape[-1],), dtype)
    out = fused_rmsnorm(x, w, gemma=gemma, interpret=True, block_rows=8)
    exp = ref.rmsnorm(x, w, gemma=gemma)
    assert rel_err(out, exp) < TOL[dtype]


def test_mlstm_parallel_equals_recurrent():
    """ref.mlstm_chunked vs a step-by-step recurrence."""
    B, L, H, D = 1, 16, 2, 8
    ks = jax.random.split(jax.random.key(6), 5)
    q = jax.random.normal(ks[0], (B, L, H, D))
    k = jax.random.normal(ks[1], (B, L, H, D))
    v = jax.random.normal(ks[2], (B, L, H, D))
    li = jax.random.normal(ks[3], (B, L, H))
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, L, H)) + 1.0)
    out = ref.mlstm_chunked(q, k, v, li, lf)

    # sequential reference
    C = jnp.zeros((B, H, D, D))
    n = jnp.zeros((B, H, D))
    m = jnp.full((B, H), -1e30)
    outs = []
    for t in range(L):
        m_new = jnp.maximum(lf[:, t] + m, li[:, t])
        i_s = jnp.exp(li[:, t] - m_new)
        f_s = jnp.exp(lf[:, t] + m - m_new)
        kf = k[:, t] * (D ** -0.5)
        C = f_s[..., None, None] * C + i_s[..., None, None] * kf[..., :, None] * v[:, t][..., None, :]
        n = f_s[..., None] * n + i_s[..., None] * kf
        num = jnp.einsum("bhd,bhdv->bhv", q[:, t], C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q[:, t], n)), jnp.exp(-m_new))
        outs.append(num / den[..., None])
        m = m_new
    exp = jnp.stack(outs, 1)
    assert rel_err(out, exp) < 1e-4


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,L,H,D,chunk", [(2, 128, 2, 32, 32), (1, 256, 4, 64, 128)])
def test_chunked_mlstm_sweep(B, L, H, D, chunk, dtype):
    from repro.kernels.mlstm_chunk import chunked_mlstm

    ks = jax.random.split(jax.random.key(7), 5)
    q = jax.random.normal(ks[0], (B, L, H, D), dtype)
    k = jax.random.normal(ks[1], (B, L, H, D), dtype)
    v = jax.random.normal(ks[2], (B, L, H, D), dtype)
    li = (jax.random.normal(ks[3], (B, L, H)) * 0.5).astype(dtype)
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, L, H)) + 1.0).astype(dtype)
    out = chunked_mlstm(q, k, v, li, lf, chunk=chunk, interpret=True)
    exp = ref.mlstm_chunked(q, k, v, li, lf)
    assert rel_err(out, exp) < (3e-2 if dtype == jnp.bfloat16 else 2e-4)
