"""The five multi-DNN applications of the paper's evaluation (Sec. IV-A).

DAG shapes mirror the cited applications:

* traffic  — SSD detector feeding parallel vehicle / pedestrian classifiers [12]
* face     — face detector -> PRNet keypoint alignment [25]
* pose     — person detector -> OpenPose estimator [26]
* caption  — frame preprocessing -> S2VT encoder -> S2VT decoder [27]
* actdet   — detector -> (tracker || re-id) -> action classifier (Caesar) [28]

Per-module request rates are the app rate scaled by a per-module *fanout*
(e.g. a detector emits several crops per frame), fixed per app as in the
frame-rate-proportionality cost model of the paper.
"""
from __future__ import annotations

from ..core.dag import AppDAG, Leaf, par, series, Workload

TRAFFIC = AppDAG(
    "traffic",
    series(Leaf("ssd_detect"), par(Leaf("vehicle_cls"), Leaf("pedestrian_cls"))),
)
FACE = AppDAG("face", series(Leaf("face_detect"), Leaf("prnet_align")))
POSE = AppDAG("pose", series(Leaf("person_detect"), Leaf("openpose")))
CAPTION = AppDAG(
    "caption", series(Leaf("frame_prep"), Leaf("s2vt_encode"), Leaf("s2vt_decode"))
)
ACTDET = AppDAG(
    "actdet",
    series(
        Leaf("act_detect"),
        par(Leaf("act_track"), Leaf("act_reid")),
        Leaf("action_cls"),
    ),
)

APPS: tuple[AppDAG, ...] = (TRAFFIC, FACE, POSE, CAPTION, ACTDET)

# requests per app-level frame for each module (fanout factors)
FANOUT: dict[str, dict[str, float]] = {
    "traffic": {"ssd_detect": 1.0, "vehicle_cls": 2.0, "pedestrian_cls": 3.0},
    "face": {"face_detect": 1.0, "prnet_align": 2.0},
    "pose": {"person_detect": 1.0, "openpose": 1.0},
    "caption": {"frame_prep": 1.0, "s2vt_encode": 1.0, "s2vt_decode": 0.5},
    "actdet": {
        "act_detect": 1.0,
        "act_track": 1.5,
        "act_reid": 1.5,
        "action_cls": 1.0,
    },
}


def app_by_name(name: str) -> AppDAG:
    for a in APPS:
        if a.name == name:
            return a
    raise KeyError(name)


def make_workload(app: AppDAG, rate: float, slo: float, tag: str = "") -> Workload:
    rates = {m: rate * FANOUT[app.name][m] for m in app.modules}
    return Workload(app, rates, slo, tag or f"{app.name}@{rate:g}/{slo:g}")
